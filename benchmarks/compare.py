"""Diff two ``BENCH_<module>.json`` files and print per-key regressions.

``python -m benchmarks.compare OLD.json NEW.json [--threshold 0.1]``

The ``benchmarks.run --json`` emitter tracks the perf trajectory across
PRs; this is the other half — given the same module's report from two
checkouts, classify every row:

  * throughput keys (``*_per_s``) regress when NEW is more than
    ``threshold`` BELOW OLD;
  * latency keys (``latency*``, ``ttft*``, ``stall*``, ``*_wall_s``)
    regress when NEW is more than ``threshold`` ABOVE OLD;
  * gate rows (0/1 in both files) regress on any 1 -> 0 flip;
  * everything else numeric is reported as an informational delta.

Exit status 1 if any key regressed, 0 otherwise — usable directly in a
shell loop over paired BENCH files.
"""
from __future__ import annotations

import argparse
import json
import sys


def _is_throughput(key: str) -> bool:
    return "req_per_s" in key or "tok_per_s" in key or "per_s" in key


def _is_latency(key: str) -> bool:
    return ("latency" in key or "ttft" in key or "stall" in key
            or key.endswith("_wall_s"))


def classify(key: str, old, new, threshold: float):
    """-> (status, detail) where status is one of 'regression', 'improved',
    'ok', 'info'."""
    if not (isinstance(old, (int, float)) and isinstance(new, (int, float))):
        return ("info", f"{old!r} -> {new!r}") if old != new else ("ok", "")
    if (isinstance(old, int) and isinstance(new, int)
            and old in (0, 1) and new in (0, 1)
            and not _is_throughput(key) and not _is_latency(key)):
        if old == 1 and new == 0:
            return "regression", "gate flipped 1 -> 0"
        if old == 0 and new == 1:
            return "improved", "gate flipped 0 -> 1"
        return "ok", f"gate {new}"
    delta = new - old
    rel = delta / abs(old) if old else (0.0 if not delta else float("inf"))
    detail = f"{old} -> {new} ({rel:+.1%})"
    if _is_throughput(key):
        if rel < -threshold:
            return "regression", detail
        return ("improved" if rel > threshold else "ok"), detail
    if _is_latency(key):
        if rel > threshold:
            return "regression", detail
        return ("improved" if rel < -threshold else "ok"), detail
    return ("info", detail) if delta else ("ok", detail)


def compare(old: dict, new: dict, threshold: float) -> list[tuple]:
    """-> [(status, key, detail)] over the union of row keys."""
    rows_old = old.get("rows", {})
    rows_new = new.get("rows", {})
    out = []
    for key in sorted(set(rows_old) | set(rows_new)):
        if key not in rows_new:
            out.append(("info", key, "removed"))
            continue
        if key not in rows_old:
            out.append(("info", key, f"new: {rows_new[key]['value']}"))
            continue
        status, detail = classify(key, rows_old[key]["value"],
                                  rows_new[key]["value"], threshold)
        out.append((status, key, detail))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("old")
    ap.add_argument("new")
    ap.add_argument("--threshold", type=float, default=0.1,
                    help="relative change treated as noise (default 0.1)")
    ap.add_argument("--all", action="store_true",
                    help="print unchanged keys too")
    args = ap.parse_args(argv)
    with open(args.old) as f:
        old = json.load(f)
    with open(args.new) as f:
        new = json.load(f)
    if old.get("name") != new.get("name"):
        print(f"warning: comparing {old.get('name')!r} "
              f"against {new.get('name')!r}", file=sys.stderr)
    results = compare(old, new, args.threshold)
    regressions = 0
    for status, key, detail in results:
        if status == "ok" and not args.all:
            continue
        if status == "regression":
            regressions += 1
        print(f"{status.upper():<10} {key}: {detail}")
    n = len(results)
    print(f"-- {n} keys, {regressions} regression(s), "
          f"threshold {args.threshold:.0%}")
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
