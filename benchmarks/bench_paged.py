"""Paged slot pool vs row slot pool at a FIXED HBM budget.

The row-granular pool provisions one ``max_len`` KV row per slot: its
concurrency is ``B`` no matter how short the requests are — the "area"
side of the paper's area-vs-reconfiguration tradeoff, paid in HBM.  The
paged pool spends the same bytes as one shared page bank; each request
holds only ``ceil((S + steps - 1)/page)`` pages, so mixed short/long
traffic packs many more concurrent requests into the same memory while
uniform worst-case traffic degenerates to exactly the row pool's
capacity.

Two measurements at one token budget (``BUDGET = B_row * MAX_LEN`` KV
token-slots, i.e. equal cache memory; the paged bank additionally pays
one park page, reported):

  * ``peak_concurrency`` — drive a short-heavy mixed burst admit-greedy
    through both pools; the peak number of simultaneously admitted
    requests.  Gate: paged >= 2x row.
  * ``uniform_tok_per_s`` — same-shape pools (equal slots, equal pages)
    under uniform-length traffic, decode throughput best-of-passes.
    Gate: paged within 10% of row.  The only extra work is reading the
    cache through the page table; measured at a serving-shaped
    cache:compute ratio (``UNIFORM_MAX_LEN``) because the CPU jnp
    reference path *materializes* the gathered view per step — a copy
    the TPU kernel never makes (its page table rides the scalar-prefetch
    DMA index map), so an inflated cache:compute ratio would benchmark
    the oracle, not the engine.

CI's bench-smoke job asserts both gates from the emitted
``BENCH_bench_paged.json``.
"""
from __future__ import annotations

import time

import numpy as np

B_ROW = 4
MAX_LEN = 256
PAGE = 64
BUDGET_PAGES = B_ROW * MAX_LEN // PAGE           # equal-memory page budget
SHORT_SEQ, LONG_SEQ = 8, 180
SHORT_STEPS, LONG_STEPS = 8, 8
UNIFORM_STEPS = 48


def _build(**extra):
    import jax
    from repro.configs import get_arch, reduced
    from repro.models.model import build_model
    cfg = reduced(get_arch("tinyllama-1.1b"), **extra)
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.key(0))


def _mixed_burst(cfg, seed=0):
    """2 long + 14 short requests, longs first (they pin pages/slots
    while the shorts pack around them)."""
    rng = np.random.default_rng(seed)

    def toks(s):
        return rng.integers(0, cfg.vocab_size, (1, s))

    reqs = [(toks(LONG_SEQ), LONG_STEPS) for _ in range(2)]
    reqs += [(toks(SHORT_SEQ), SHORT_STEPS) for _ in range(14)]
    return reqs


def _peak_concurrency(eng, p, reqs):
    """Admit-greedy drive; returns the peak simultaneously-admitted
    request count (live + mid-prefill rows)."""
    queue = list(reqs)
    peak = 0
    while queue or eng.live_slots():
        while queue and eng.can_admit(queue[0][0], queue[0][1]):
            toks, steps = queue.pop(0)
            eng.admit(p, toks, max_new=steps)
        peak = max(peak, eng.live_slots())
        if eng.live_slots():
            eng.step(p)
    return peak


def _uniform_pass(eng, p, toks):
    """One timed decode pass (admission and compile outside the timed
    region); returns tokens/s."""
    import jax
    eng.reset()
    eng.admit(p, toks, max_new=UNIFORM_STEPS)
    jax.block_until_ready(eng.state.tok)
    t0 = time.perf_counter()
    n = 0
    while eng.live_slots():
        eng.step(p)
        n += B_ROW
    jax.block_until_ready(eng.state.tok)
    return n / (time.perf_counter() - t0)


def _uniform_tok_per_s(engines, p, cfg, passes=5):
    """Uniform-length traffic, all pools at the same concurrency:
    best-of-passes per engine, passes INTERLEAVED across engines so a
    system-noise burst cannot hit one engine's whole sample (CPU CI
    runners are contended)."""
    rng = np.random.default_rng(1)
    toks = rng.integers(0, cfg.vocab_size, (B_ROW, SHORT_SEQ))
    for eng in engines:
        _uniform_pass(eng, p, toks)        # warm pass: all compiles
    best = [0.0] * len(engines)
    for _ in range(passes):
        for i, eng in enumerate(engines):
            best[i] = max(best[i], _uniform_pass(eng, p, toks))
    return best


def run() -> list[tuple]:
    from repro.serve.engine import StepEngine
    cfg, m, p = _build()
    budget_note = (f"budget {B_ROW * MAX_LEN} KV token-slots "
                   f"({BUDGET_PAGES} pages of {PAGE}; paged pays +1 park)")

    row = StepEngine(m, batch_size=B_ROW, max_len=MAX_LEN)
    paged = StepEngine(m, batch_size=16, max_len=MAX_LEN, paged=True,
                       page_size=PAGE, num_pages=BUDGET_PAGES + 1)
    peak_row = _peak_concurrency(row, p, _mixed_burst(cfg))
    peak_paged = _peak_concurrency(paged, p, _mixed_burst(cfg))

    # uniform traffic: same slots, same page budget — throughput parity.
    # A serving-shaped model (wider d_model; the KV cache per step is
    # unchanged) so the step measures engine overhead at a realistic
    # cache:compute ratio — the jnp oracle path materializes the
    # page-table gather the TPU kernel's index map makes for free.
    cfg_u, m_u, p_u = _build(d_model=256, d_ff=512)
    row_u = StepEngine(m_u, batch_size=B_ROW, max_len=MAX_LEN)
    paged_u = StepEngine(m_u, batch_size=B_ROW, max_len=MAX_LEN,
                         paged=True, page_size=PAGE,
                         num_pages=BUDGET_PAGES + 1)
    tps_row, tps_paged = _uniform_tok_per_s([row_u, paged_u], p_u, cfg_u)
    ratio = tps_paged / tps_row if tps_row else 0.0

    rows = [
        ("row_peak_concurrency", peak_row, budget_note),
        ("paged_peak_concurrency", peak_paged,
         f"mixed burst: 2 long ({LONG_SEQ}t) + 14 short ({SHORT_SEQ}t)"),
        ("row_uniform_tok_per_s", round(tps_row, 1), ""),
        ("paged_uniform_tok_per_s", round(tps_paged, 1),
         f"uniform {SHORT_SEQ}t prompts x {UNIFORM_STEPS} steps, "
         f"best of 5 interleaved passes"),
        ("paged_concurrency_2x",
         int(peak_paged >= 2 * peak_row),
         f"{peak_paged} vs {peak_row} concurrent at equal memory"),
        ("paged_uniform_within_10pct", int(ratio >= 0.9),
         f"paged/row tok/s ratio {ratio:.3f}"),
        # prefix-sharing counters ride in every paged engine's stats
        # (zero here: this bench runs with prefix_cache off — the
        # sharing numbers live in BENCH_bench_prefix.json)
        ("prefix_hits", paged.stats.get("prefix_hits", 0),
         "prefix_cache off in this bench"),
        ("cache_evictions", paged.stats.get("cache_evictions", 0), ""),
    ]
    return rows


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    for row in run():
        print(*row, sep=",")
