"""Fig 5(a/b): primitive area / delay / power vs the paper's claims."""
from __future__ import annotations

from repro.core import hwmodel as hw


def run() -> list[tuple]:
    rows = []
    for kind in ("CB", "LUT"):
        sram = hw.AREA_LAMBDA2[kind]["sram_1cfg"]
        for tech, area in hw.AREA_LAMBDA2[kind].items():
            ratio = area / sram
            claim = hw.AREA_RATIO_CLAIMS.get((kind, tech))
            ok = claim is None or abs(ratio - claim) < 0.005
            rows.append((f"fig5a_area_{kind}_{tech}", area,
                         f"ratio={ratio:.3f}"
                         + (f" claim={claim:.3f} {'OK' if ok else 'MISS'}"
                            if claim else "")))
    for kind, red in hw.HEADLINE_AREA_REDUCTION.items():
        got = 1 - hw.AREA_LAMBDA2[kind]["fefet_2cfg"] / \
            hw.AREA_LAMBDA2[kind]["sram_1cfg"]
        rows.append((f"fig5a_headline_{kind}_reduction", got,
                     f"claim={red:.3f} {'OK' if abs(got - red) < 0.005 else 'MISS'}"))
    for tech, d in hw.LUT_READ_DELAY_PS.items():
        rows.append((f"fig5b_lut_delay_ps_{tech}", d, ""))
    for tech, p in hw.LUT_READ_POWER_UW.items():
        rows.append((f"fig5b_lut_power_uw_{tech}", p, ""))
    for tech, d in hw.CB_DELAY_PS.items():
        rows.append((f"fig5b_cb_delay_ps_{tech}", d, ""))
    rows.append(("fig5b_cb_power_reduction_vs_sram",
                 hw.CB_POWER_REDUCTION["fefet_vs_sram"], "claim 82.7%"))
    rows.append(("fig5b_sb_power_reduction_vs_sram",
                 hw.SB_POWER_REDUCTION["fefet_vs_sram"], "claim 53.6%"))
    return rows
