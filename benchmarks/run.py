"""Benchmark driver: one module per paper table/figure + ours.

``PYTHONPATH=src python -m benchmarks.run``   prints ``name,value,notes``
CSV; ``--only fig6`` filters by prefix; ``--json [DIR]`` additionally
writes one machine-readable ``BENCH_<name>.json`` per module (throughput
and latency fields pulled out of the rows, plus platform / device /
jax-version / git-sha provenance in ``meta``) so the perf trajectory can
be tracked across PRs — ``python -m benchmarks.compare OLD NEW`` diffs
two emitted files and prints per-key regressions.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback


def modules():
    from benchmarks import (bench_continuous, bench_multistep, bench_paged,
                            bench_prefill_chunk, bench_prefix,
                            bench_serve_queue, bench_sharded,
                            bench_speculative, bench_switch,
                            fig5_critical_path, fig5_primitives, fig6_cases,
                            fig6b_accuracy, figS1_pipeline, roofline_table)
    return [
        ("fig5_primitives", fig5_primitives.run),
        ("fig5_critical_path", fig5_critical_path.run),
        ("fig6b_accuracy", fig6b_accuracy.run),
        ("fig6_cases", fig6_cases.run),
        ("figS1_pipeline", figS1_pipeline.run),
        ("bench_switch", bench_switch.run),
        ("bench_serve_queue", bench_serve_queue.run),
        ("bench_continuous", bench_continuous.run),
        ("bench_speculative", bench_speculative.run),
        ("bench_prefill_chunk", bench_prefill_chunk.run),
        ("bench_paged", bench_paged.run),
        ("bench_prefix", bench_prefix.run),
        ("bench_sharded", bench_sharded.run),
        ("bench_multistep", bench_multistep.run),
        ("roofline_table", roofline_table.run),
    ]


def _metadata() -> dict:
    """Where these numbers came from: BENCH files are diffed across PRs
    and machines (``benchmarks.compare``), so each one records the
    platform, the JAX device/version, and the git revision it measured."""
    import platform
    import subprocess

    import jax
    try:
        sha = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True,
                             timeout=10).stdout.strip() or "unknown"
    except Exception:
        sha = "unknown"
    dev = jax.devices()[0]
    from repro.core import env
    return {"platform": platform.platform(),
            "device": f"{dev.platform}:{dev.device_kind}",
            "jax_version": jax.__version__,
            "git_sha": sha,
            **env.describe()}


def _json_report(name: str, rows: list[tuple], wall_s: float) -> dict:
    """Shape a module's CSV rows into the tracked-metrics JSON: every row
    keyed by name, with throughput / latency / hidden-load convenience
    sections so cross-PR tooling doesn't parse notes strings."""
    report: dict = {"name": name, "wall_s": round(wall_s, 3),
                    "rows": {}, "throughput": {}, "latency": {}}
    for row in rows:
        n, v, note = (tuple(row) + ("",))[:3]
        report["rows"][str(n)] = {"value": v, "notes": str(note)}
        key = str(n)
        if "req_per_s" in key or "tok_per_s" in key or "per_s" in key:
            report["throughput"][key] = v
        if ("latency" in key or "ttft" in key or "stall" in key
                or key.endswith("_wall_s")):
            report["latency"][key] = v
        if "hidden_load_fraction" in key:
            report.setdefault("hidden_load", {})[key] = v
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", nargs="?", const=".", default=None,
                    metavar="DIR",
                    help="also write BENCH_<name>.json per module to DIR")
    args = ap.parse_args(argv)
    meta = None
    if args.json is not None:
        os.makedirs(args.json, exist_ok=True)
        meta = _metadata()
    failures = 0
    print("name,value,notes")
    for name, fn in modules():
        if args.only and not name.startswith(args.only):
            continue
        t0 = time.perf_counter()
        try:
            rows = list(fn())
            for row in rows:
                n, v, note = (tuple(row) + ("",))[:3]
                print(f"{n},{v},{note}")
        except Exception:
            failures += 1
            rows = None
            print(f"{name},ERROR,")
            traceback.print_exc()
        wall = time.perf_counter() - t0
        print(f"_{name}_wall_s,{wall:.2f},")
        if args.json is not None:
            path = os.path.join(args.json, f"BENCH_{name}.json")
            report = (_json_report(name, rows, wall) if rows is not None
                      else {"name": name, "error": True,
                            "wall_s": round(wall, 3)})
            report["meta"] = meta
            with open(path, "w") as f:
                json.dump(report, f, indent=1, sort_keys=True)
                f.write("\n")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
