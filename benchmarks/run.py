"""Benchmark driver: one module per paper table/figure + ours.

``PYTHONPATH=src python -m benchmarks.run``   prints ``name,value,notes``
CSV; ``--only fig6`` filters by prefix.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def modules():
    from benchmarks import (bench_serve_queue, bench_switch,
                            fig5_critical_path, fig5_primitives, fig6_cases,
                            fig6b_accuracy, figS1_pipeline, roofline_table)
    return [
        ("fig5_primitives", fig5_primitives.run),
        ("fig5_critical_path", fig5_critical_path.run),
        ("fig6b_accuracy", fig6b_accuracy.run),
        ("fig6_cases", fig6_cases.run),
        ("figS1_pipeline", figS1_pipeline.run),
        ("bench_switch", bench_switch.run),
        ("bench_serve_queue", bench_serve_queue.run),
        ("roofline_table", roofline_table.run),
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    failures = 0
    print("name,value,notes")
    for name, fn in modules():
        if args.only and not name.startswith(args.only):
            continue
        t0 = time.perf_counter()
        try:
            for row in fn():
                n, v, note = (tuple(row) + ("",))[:3]
                print(f"{n},{v},{note}")
        except Exception:
            failures += 1
            print(f"{name},ERROR,")
            traceback.print_exc()
        print(f"_{name}_wall_s,{time.perf_counter() - t0:.2f},")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
