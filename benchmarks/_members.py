"""Likelihood-based cascade members for the Fig 6(b) benchmark (fast,
deterministic stand-ins for trained classifiers; the trained version lives
in examples/train_cascade.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cascade import CascadeMember


def build_cascade_members(task, noise: float = 0.5, spec_noise: float = 0.0,
                          seed: int = 0):
    """``noise`` blurs the generalist's likelihoods (limited capacity across
    all subclasses); ``spec_noise`` blurs the specialists (they are better,
    not perfect — tuned so the accuracy gap lands in the paper's ~3 % range
    rather than a toy 100 %-vs-x% gap)."""
    rng = np.random.default_rng(seed)
    logd = np.log(task.dists + 1e-9)
    sup_of = task.sub_of_super

    def counts(x):
        return jax.vmap(lambda r: jnp.bincount(r, length=task.vocab))(x)

    def super_fn(params, x):
        c = counts(x).astype(jnp.float32)
        sub_ll = c @ params["logd"].T
        sup_ll = jnp.zeros((x.shape[0], task.num_super))
        return sup_ll.at[:, params["sup_of"]].add(
            jax.nn.softmax(sub_ll, -1))

    def gen_fn(params, x):
        c = counts(x).astype(jnp.float32)
        return c @ params["logd"].T

    def spec_fn(params, x):
        c = counts(x).astype(jnp.float32)
        return c @ params["logd"].T

    noisy = logd + rng.normal(0, noise, logd.shape)
    sup = CascadeMember("super", super_fn,
                        lambda: {"logd": jnp.asarray(logd, jnp.float32),
                                 "sup_of": jnp.asarray(sup_of)})
    gen = CascadeMember("generalist", gen_fn,
                        lambda: {"logd": jnp.asarray(noisy, jnp.float32)})
    specs = []
    for g in range(task.num_super):
        subs = np.where(sup_of == g)[0]
        sl = logd[subs] + rng.normal(0, spec_noise, logd[subs].shape)
        specs.append(CascadeMember(
            f"spec{g}", spec_fn,
            lambda sl=sl: {"logd": jnp.asarray(sl, jnp.float32)},
            covers=g))
    return sup, gen, specs
