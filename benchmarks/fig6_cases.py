"""Fig 6(d), Fig 6(f), Fig S9(c): the three timing case studies.

Analytic numbers come from the calibrated workload (benchmarks/calibrate)
through the discrete-event scheduler; each case also runs LIVE on a real
ContextSwitchEngine with synthetic weight payloads whose load/exec times
mirror the calibrated ratios (scaled to keep the benchmark < 1 min).
"""
from __future__ import annotations

import itertools
import time

import jax.numpy as jnp

from benchmarks.calibrate import (
    CASE2_BATCHES, NET_NAMES, TARGETS, calibrated, case2_savings,
    case3_savings, patched_savings)
from repro.core.context import ContextDescriptor, ContextSwitchEngine
from repro.core.scheduler import (
    Run, run_schedule_live, time_saving)


def _fmt(v):
    return round(float(v), 4)


def run_analytic() -> list[tuple]:
    execs, loads, stats = calibrated()
    rows = [("calib_exec_ms_" + n, _fmt(execs[n] * 1e3), "")
            for n in NET_NAMES]
    rows += [("calib_load_ms_" + n, _fmt(loads[n] * 1e3),
              "bitstream/ICAP model") for n in NET_NAMES]
    c2 = case2_savings(execs, loads)
    i = 0
    for a, b in itertools.combinations(NET_NAMES, 2):
        for n in CASE2_BATCHES:
            rows.append((f"fig6d_saving_{a}+{b}_x{n}", _fmt(c2[i]), ""))
            i += 1
    for key in ("case2_min", "case2_max", "case2_mean"):
        rows.append((f"fig6d_{key}", _fmt(stats[key]),
                     f"paper={TARGETS[key]}"))
    c3 = case3_savings(execs, loads, stats["k3"])
    for order, s in zip(itertools.permutations(NET_NAMES), c3):
        rows.append(("fig6f_saving_" + ">".join(o[:3] for o in order),
                     _fmt(s), ""))
    for key in ("case3_min", "case3_max"):
        rows.append((f"fig6f_{key}", _fmt(stats[key]),
                     f"paper={TARGETS[key]} (ideal bound 0.5)"))
    pa = patched_savings(execs, loads)
    rows.append(("figS9_patched_max", _fmt(max(pa)),
                 f"paper={TARGETS['patched_max']}"))
    rows.append(("figS9_patched_min", _fmt(min(pa)), "paper=0.1132"))
    return rows


def _mk_engine(load_ms: dict, dim: int = 256) -> ContextSwitchEngine:
    eng = ContextSwitchEngine(num_slots=2)
    for name, ms in load_ms.items():
        def weights_fn(ms=ms):
            time.sleep(ms / 1e3)            # stands in for H2D streaming
            return {"w": jnp.eye(dim)}
        eng.register(ContextDescriptor(name=name,
                                       apply_fn=lambda p, x: x @ p["w"],
                                       weights_fn=weights_fn))
    return eng


def run_live(scale: float = 0.2) -> list[tuple]:
    """Drive the real engine with the calibrated schedule (time-scaled)."""
    execs, loads, stats = calibrated()
    load_ms = {n: max(loads[n] * 1e3 * scale, 1.0) for n in NET_NAMES}
    exec_reps = {n: max(int(execs[n] / 0.0005), 1) for n in NET_NAMES}
    rows = []

    # case 2: alternate two preloaded nets
    a, b = "resnet50", "cnv"
    sched = [Run(a, 0, exec_reps[a]), Run(b, 0, exec_reps[b])] * 3
    inputs = {n: (jnp.ones((64, 256)),) for n in NET_NAMES}
    eng = _mk_engine(load_ms)
    eng.preload(a, block=True)
    eng.preload(b, block=True)              # preloaded: off the clock
    dyn = run_schedule_live(eng, sched, inputs, dynamic=True)
    eng.shutdown()
    eng = _mk_engine(load_ms)
    conv = run_schedule_live(eng, sched, inputs, dynamic=False)
    eng.shutdown()
    s_live = time_saving(conv["total"], dyn["total"])
    rows.append(("live_case2_saving", _fmt(s_live),
                 f"conv={conv['total']:.3f}s ours={dyn['total']:.3f}s"))

    # case 3: three nets, dynamic reconfiguration (2 slots)
    order = list(NET_NAMES)
    sched3 = [Run(n, 0, max(int(execs[n] * stats['k3'] / 0.0005), 1))
              for n in order]
    eng = _mk_engine(load_ms)
    dyn3 = run_schedule_live(eng, sched3, inputs, dynamic=True)
    eng.shutdown()
    eng = _mk_engine(load_ms)
    conv3 = run_schedule_live(eng, sched3, inputs, dynamic=False)
    eng.shutdown()
    rows.append(("live_case3_saving",
                 _fmt(time_saving(conv3["total"], dyn3["total"])),
                 f"conv={conv3['total']:.3f}s ours={dyn3['total']:.3f}s "
                 f"stalls={dyn3['visible_stalls']:.3f}s"))
    return rows


def run() -> list[tuple]:
    return run_analytic() + run_live()
