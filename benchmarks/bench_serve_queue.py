"""End-to-end serving benchmark: the switch-aware async scheduler vs naive
FIFO under mixed multi-model traffic — the repo's first request-level
serving performance number.

A 3-model zoo on a dual-slot engine (the paper's design point: one more
model than fits) serves an interleaved request stream two ways:

  * FIFO  — arrival order, one switch per model change, next model
            prefetched into the shadow slot (in-order serving)
  * queue — ``SwitchScheduler``: same-model requests coalesce into
            streaks, next context ranked by queue pressure + load cost,
            shadow-slot prefetch behind the active streak

``weights_fn`` sleeps ``LOAD_EMU_S`` to emulate streaming real model
weights over the host->device link (the reduced CPU test models are
in-memory, so raw device_put is microseconds; the paper's contexts are
not).  Each mode is warmed with one full untimed pass (jit compilation,
incl. the scheduler's stacked shapes), then measured in steady state.

Reported: throughput, p50/p99 request latency, context changes, loads,
and the hidden-load fraction (how much reconfiguration the traffic
shaping hid — the paper's 78.7 %/20.3 % headline at serving granularity).
"""
from __future__ import annotations

import time

import numpy as np

MODELS = ["supersub-super", "supersub-sub", "tinyllama-1.1b"]
LOAD_EMU_S = 0.03     # emulated weight-streaming time per context load


def _build(names, slots, max_len):
    from repro.launch.serve import build_server
    return build_server(names, slots, max_len, load_delay_s=LOAD_EMU_S)


def _reset_stats(server):
    for k, v in server.engine.stats.items():
        server.engine.stats[k] = 0 if isinstance(v, int) else 0.0


def _run_fifo(server, reqs):
    t0 = time.perf_counter()
    lat = []
    for i, (name, toks) in enumerate(reqs):
        server.engine.preload(name)
        server.engine.switch(name, wait=True)
        server.engine.prefetch([n for n, _ in reqs[i + 1:]], limit=1)
        server.serve_batch(name, toks)
        lat.append(time.perf_counter() - t0)     # completion time since t0
    return time.perf_counter() - t0, lat


def _run_queue(server, reqs):
    from repro.serve.scheduler import SwitchScheduler
    done_at = [0.0] * len(reqs)
    with SwitchScheduler(server) as sched:
        t0 = time.perf_counter()
        futs = []
        for i, (n, t) in enumerate(reqs):
            f = sched.submit(n, t)
            f.add_done_callback(
                lambda _, i=i: done_at.__setitem__(
                    i, time.perf_counter()))
            futs.append(f)
        for i, f in enumerate(futs):
            f.result()
            if done_at[i] == 0.0:    # result() can beat the done-callback
                done_at[i] = time.perf_counter()
    return time.perf_counter() - t0, [d - t0 for d in done_at]


def run(n_requests: int = 24, batch: int = 2, seq: int = 16,
        slots: int = 2, seed: int = 0) -> list[tuple]:
    from repro.launch.serve import request_stream

    rows = []
    results = {}
    for mode, driver in (("fifo", _run_fifo), ("queue", _run_queue)):
        server, cfgs = _build(MODELS, slots, seq + 8)
        reqs = list(request_stream(MODELS, cfgs, n_requests,
                                   batch, seq, seed))
        driver(server, reqs)                     # warm pass: jit + first load
        _reset_stats(server)
        wall, lat = driver(server, reqs)         # steady-state measurement

        stats = dict(server.engine.stats)
        hidden = server.engine.hidden_load_fraction()
        results[mode] = {"wall": wall, "changes": stats["context_changes"]}
        rows += [
            (f"serve_{mode}_wall_s", round(wall, 3),
             f"{n_requests} reqs x {len(MODELS)} models, {slots} slots"),
            (f"serve_{mode}_req_per_s", round(n_requests / wall, 2), ""),
            (f"serve_{mode}_latency_p50_s",
             round(float(np.percentile(lat, 50)), 4), ""),
            (f"serve_{mode}_latency_p99_s",
             round(float(np.percentile(lat, 99)), 4), ""),
            (f"serve_{mode}_context_changes", stats["context_changes"],
             "actual select-signal flips"),
            (f"serve_{mode}_loads", stats["loads"],
             f"~{int(LOAD_EMU_S * 1e3)}ms emulated streaming each"),
            (f"serve_{mode}_hidden_load_fraction", round(hidden, 3),
             "reconfiguration hidden behind execution"),
        ]
        server.shutdown()

    fewer = results["queue"]["changes"] < results["fifo"]["changes"]
    not_slower = results["queue"]["wall"] <= results["fifo"]["wall"] * 1.05
    rows.append(("serve_queue_fewer_switches", int(fewer),
                 "coalescing must beat FIFO on switches"))
    rows.append(("serve_queue_wall_ok", int(not_slower),
                 "queue wall <= 1.05x fifo"))
    return rows


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    for row in run():
        print(*row, sep=",")
