"""Calibration of the paper's unpublished workload constants.

The paper publishes (i) the reconfiguration-time formula (bitstream bits /
3.2 Gb/s ICAP), (ii) the bitstream-scale of the three Vitis-AI networks,
and (iii) the *resulting saving ranges* of its case studies — but not the
absolute DPU execution latencies.  We therefore treat the three per-network
execution times as free parameters and fit them so the published statistics
are reproduced (DESIGN.md §9, assumption 5):

  Fig 6(d)  two preloaded configs:   savings 39.0 % .. 97.5 %, mean 78.7 %
  Fig 6(f)  three-net dynamic cycle: savings  2.4 % .. 37.4 % (bound 50 %)
  Fig S9(c) patched (run 5x, then switch): max ~ 88.42 %

The fit uses the same discrete-event simulator that drives the live engine,
so the validated quantity is the *scheduling model*, not a curve fit.
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.core.hwmodel import NETWORKS, reconfig_time_s
from repro.core.scheduler import (
    Run, simulate_conventional, simulate_dynamic, simulate_preloaded,
    time_saving)

NET_NAMES = ("resnet50", "cnv", "mobilenetv1")
# starting point (order-of-magnitude defaults from hwmodel); the fit below
# refines both bitstream sizes and exec times, since the paper publishes
# neither — only the ICAP formula and the resulting saving statistics.
DEFAULT_LOADS_S = {n: reconfig_time_s(NETWORKS[n][0]) for n in NET_NAMES}

# Fig 6(d): the paper switches between two preloaded networks "frequently";
# the per-case knob is how many inferences run between switches.
CASE2_BATCHES = (1, 5, 20)

TARGETS = {
    "case2_min": 0.390, "case2_max": 0.975, "case2_mean": 0.787,
    "case3_min": 0.024, "case3_max": 0.374,
    "patched_max": 0.8842,
}


def case2_savings(execs: dict, loads: dict) -> list[float]:
    """Two preloaded configurations (Fig 6c/d)."""
    out = []
    for a, b in itertools.combinations(NET_NAMES, 2):
        for n in CASE2_BATCHES:
            sched = [Run(a, execs[a], n), Run(b, execs[b], n)] * 4
            conv = simulate_conventional(sched, loads)
            ours = simulate_preloaded(sched, loads)
            out.append(time_saving(conv, ours))
    return out


def case3_savings(execs: dict, loads: dict,
                  k3: float = 1.0) -> list[float]:
    """Three networks, dynamic reconfiguration, 6 orders (Fig 6e/f).

    ``k3`` is the images-per-activation of this case study (the paper's
    case 2 and case 3 are separate experiments; only the saving statistics
    are published, so the workload size per run is a per-case free
    parameter)."""
    out = []
    for order in itertools.permutations(NET_NAMES):
        sched = [Run(n, execs[n] * k3) for n in order]
        conv = simulate_conventional(sched, loads)
        ours = simulate_dynamic(sched, loads, num_slots=2)
        out.append(time_saving(conv, ours))
    return out


def patched_savings(execs: dict, loads: dict,
                    repeats: int = 5) -> list[float]:
    """Fig S9(c): execute the first network `repeats` times, then switch."""
    out = []
    for a, b in itertools.permutations(NET_NAMES, 2):
        sched = [Run(a, execs[a], repeats), Run(b, execs[b], 1)] * 3
        conv = simulate_conventional(sched, loads)
        ours = simulate_preloaded(sched, loads)
        out.append(time_saving(conv, ours))
    return out


def stats_for(execs: dict, loads: dict, k3: float = 1.0) -> dict:
    c2 = case2_savings(execs, loads)
    c3 = case3_savings(execs, loads, k3)
    pa = patched_savings(execs, loads)
    return {
        "case2_min": min(c2), "case2_max": max(c2),
        "case2_mean": float(np.mean(c2)),
        "case3_min": min(c3), "case3_max": max(c3),
        "patched_max": max(pa),
    }


def _loss(execs: dict, loads: dict, k3: float = 1.0):
    stats = stats_for(execs, loads, k3)
    return sum((stats[k] - v) ** 2 for k, v in TARGETS.items()), stats


def fit_workload(seed: int = 0, iters: int = 8000) -> tuple[dict, dict, dict]:
    """Deterministic random-restart search over per-net (exec, bitstream).

    Returns (execs_s, loads_s, achieved_stats).  Structured seeds encode
    the feasibility analysis: case-3's 37.4 % max needs two nets whose
    exec ~ the next net's load (the paper's own 'ideal 50 %' condition)
    plus one light net; case-2's 97.5 % max needs a pair whose joint load
    dwarfs its exec."""
    rng = np.random.default_rng(seed)
    best_e, best_l, best_loss, best_stats = None, None, np.inf, None

    best_k = 1.0
    seeds = [
        ({"resnet50": 4e-3, "cnv": 4e-3, "mobilenetv1": 0.1e-3},
         {"resnet50": 60e-3, "cnv": 60e-3, "mobilenetv1": 4e-3}, 15.0),
        ({"resnet50": 3.5e-3, "cnv": 0.3e-3, "mobilenetv1": 3e-3},
         {"resnet50": 55e-3, "cnv": 5e-3, "mobilenetv1": 60e-3}, 18.0),
    ]

    def sample():
        execs = {n: 10 ** rng.uniform(-4.5, -0.5) for n in NET_NAMES}
        loads = {n: reconfig_time_s(10 ** rng.uniform(1.0, 2.8))
                 for n in NET_NAMES}        # 10 Mb .. 630 Mb bitstreams
        return execs, loads, 10 ** rng.uniform(0, 2.5)

    cands = seeds + [sample() for _ in range(iters)]
    for execs, loads, k3 in cands:
        loss, stats = _loss(execs, loads, k3)
        if loss < best_loss:
            best_e, best_l, best_k, best_loss, best_stats = \
                execs, loads, k3, loss, stats
    for i in range(12000):                  # local refinement (annealed)
        sig = 0.15 * (1.0 - i / 12000) + 0.01
        e = {n: v * 10 ** rng.normal(0, sig) for n, v in best_e.items()}
        l = {n: v * 10 ** rng.normal(0, sig) for n, v in best_l.items()}
        k = best_k * 10 ** rng.normal(0, sig)
        loss, stats = _loss(e, l, k)
        if loss < best_loss:
            best_e, best_l, best_k, best_loss, best_stats = \
                e, l, k, loss, stats
    return best_e, best_l, {"k3": best_k, **best_stats}


_CACHE = None


def calibrated() -> tuple[dict, dict, dict]:
    global _CACHE
    if _CACHE is None:
        _CACHE = fit_workload()
    return _CACHE
