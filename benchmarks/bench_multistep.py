"""Device-resident multi-step decode + int8 page bank: the two levers
for serving density.

Part A — host-sync amortization.  A single-step engine pays one host
round-trip (read back the sampled token, run the rank/drain/admit tick)
per decoded token.  ``multi_step=T`` fuses up to T decode steps into one
jitted device loop, so at steady state the engine syncs once per T
tokens.  Measured directly off the engine's tick counters
(``host_ticks`` = device->host syncs, ``device_steps`` = committed
tokens).  Gate: syncs/token < 1.5/T at steady state — i.e. the fused
engine actually amortizes, with 50% slack for ramp-down ticks at stream
tails.

Part B — int8 pages at a FIXED HBM budget.  An int8 page stores
``hd + 4`` bytes per token-head (codes + f32 scale) vs ``2*hd`` for
bf16 — at ``head_dim=64`` that is 1.88x more pages in the same bytes
(the reduced test models' hd=32 would cap at 1.78x; serving-shaped
heads are what the bank is for).  The page budget is computed from the
MEASURED ``nbytes`` of the two pool layouts, then both engines take an
admit-greedy burst of short requests.  Gate: int8 peak admitted
concurrency >= 1.8x bf16.

CI's bench-smoke job asserts both gates from
``BENCH_bench_multistep.json``.
"""
from __future__ import annotations

import time

import numpy as np

T = 8                   # fused steps per tick
A_BATCH = 4
A_MAX_LEN = 64
A_STEPS = 33            # 32 decode steps: 4 full fused ticks at T=8

PAGE = 16
B_MAX_LEN = 32          # short requests: seq 16 + 16 new = 2 pages each
B_SEQ, B_STEPS = 16, 16
FP16_PAGES = 32         # allocatable page budget for the bf16 bank
B_SLOTS = 48


def _build(**extra):
    import jax
    from repro.configs import get_arch, reduced
    from repro.models.model import build_model
    cfg = reduced(get_arch("tinyllama-1.1b"), **extra)
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.key(0))


# ---------------------------------------------------------------- part A

def _amortization_pass(m, p, cfg, multi_step):
    """One full stream: admit a uniform batch, drain, return the tick
    counters and wall-clock tokens/s."""
    import jax
    from repro.serve.engine import StepEngine
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (A_BATCH, 8))
    eng = StepEngine(m, batch_size=A_BATCH, max_len=A_MAX_LEN,
                     multi_step=multi_step)
    eng.admit(p, toks, max_new=A_STEPS)    # compiles happen here
    jax.block_until_ready(eng.state.tok)
    t0 = time.perf_counter()
    while eng.live_slots():
        eng.step(p)
    jax.block_until_ready(eng.state.tok)
    wall = time.perf_counter() - t0
    return eng.stats["host_ticks"], eng.stats["device_steps"], wall


# ---------------------------------------------------------------- part B

def _page_bytes(m, quantized):
    """Measured bytes per page across all layers of one bank layout."""
    import jax
    pools = m.init_page_pool(2, PAGE, abstract=True, quantized=quantized)
    total = sum(leaf.size * leaf.dtype.itemsize
                for leaf in jax.tree.leaves(pools))
    return total // 2                      # 2 pages in the probe pool


def _peak_concurrency(eng, p, reqs):
    """Admit-greedy drive (same contract as bench_paged): peak number of
    simultaneously admitted requests."""
    queue = list(reqs)
    peak = 0
    while queue or eng.live_slots():
        while queue and eng.can_admit(queue[0][0], queue[0][1]):
            toks, steps = queue.pop(0)
            eng.admit(p, toks, max_new=steps)
        peak = max(peak, eng.live_slots())
        if eng.live_slots():
            eng.step(p)
    return peak


def run() -> list[tuple]:
    from repro.serve.engine import StepEngine

    # A: host syncs per token, single-step vs fused
    cfg, m, p = _build()
    t1_ticks, t1_steps, t1_wall = _amortization_pass(m, p, cfg, 1)
    tT_ticks, tT_steps, tT_wall = _amortization_pass(m, p, cfg, T)
    spt = tT_ticks / tT_steps
    n_tok = A_BATCH * (A_STEPS - 1)

    # B: admit-greedy concurrency at a measured fixed byte budget.
    # Serving-shaped heads (hd=64): the scale overhead is 1/16 of the
    # page instead of 1/8, which is what buys the 1.88x page count.
    cfg_q, m_q, p_q = _build(head_dim=64)
    fp16_pb = _page_bytes(m_q, quantized=False)
    int8_pb = _page_bytes(m_q, quantized=True)
    budget = (FP16_PAGES + 1) * fp16_pb    # +1: the park page
    int8_pages = budget // int8_pb - 1
    rng = np.random.default_rng(1)
    reqs = [(rng.integers(0, cfg_q.vocab_size, (1, B_SEQ)), B_STEPS)
            for _ in range(B_SLOTS)]
    peaks = {}
    for name, quant, npages in (("fp16", None, FP16_PAGES),
                                ("int8", "int8", int8_pages)):
        eng = StepEngine(m_q, batch_size=B_SLOTS, max_len=B_MAX_LEN,
                         paged=True, page_size=PAGE,
                         num_pages=npages + 1, quantize_kv=quant)
        peaks[name] = _peak_concurrency(eng, p_q, list(reqs))
    ratio = peaks["int8"] / peaks["fp16"] if peaks["fp16"] else 0.0

    return [
        ("multistep_t1_host_ticks", t1_ticks,
         f"{A_BATCH} rows x {A_STEPS - 1} decode steps"),
        (f"multistep_t{T}_host_ticks", tT_ticks,
         f"same stream, multi_step={T}"),
        (f"multistep_t{T}_syncs_per_token", round(spt, 4),
         f"host_ticks/device_steps; single-step pays "
         f"{t1_ticks / t1_steps:.2f}"),
        ("multistep_t1_tok_per_s", round(n_tok / t1_wall, 1), ""),
        (f"multistep_t{T}_tok_per_s", round(n_tok / tT_wall, 1), ""),
        ("multistep_syncs_amortized", int(spt < 1.5 / T),
         f"{spt:.4f} < {1.5 / T:.4f} (1.5/T at T={T})"),
        ("fp16_page_kib", round(fp16_pb / 1024, 2),
         f"page={PAGE} tokens, head_dim=64, all layers"),
        ("int8_page_kib", round(int8_pb / 1024, 2),
         "codes + per-token-per-head f32 scales"),
        ("int8_pages_at_budget", int(int8_pages),
         f"vs {FP16_PAGES} bf16 pages in {budget // 1024} KiB"),
        ("fp16_peak_concurrency", peaks["fp16"],
         f"admit-greedy, {B_SLOTS} reqs of {B_SEQ}t + {B_STEPS} new"),
        ("int8_peak_concurrency", peaks["int8"], "same burst"),
        ("int8_concurrency_1_8x", int(ratio >= 1.8),
         f"{peaks['int8']} vs {peaks['fp16']} concurrent "
         f"({ratio:.2f}x) at equal bytes"),
    ]


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    for row in run():
        print(*row, sep=",")
