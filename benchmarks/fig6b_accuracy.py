"""Fig 6(a/b): dynamic (Super-Sub cascade) vs static inference accuracy.

Uses the hierarchical synthetic task + likelihood-based members (fast,
deterministic); examples/train_cascade.py shows the same effect with
*trained* transformer classifiers through the same engine.
"""
from __future__ import annotations

import numpy as np

from benchmarks._members import build_cascade_members
from repro.core.context import ContextSwitchEngine
from repro.train.data import HierarchicalTask


def run() -> list[tuple]:
    from repro.core.cascade import SuperSubCascade
    task = HierarchicalTask(num_super=8, subs_per_super=6, vocab=128,
                            seq_len=32, seed=0)
    sup, gen, specs = build_cascade_members(task, noise=0.06,
                                            spec_noise=0.05)
    eng = ContextSwitchEngine(num_slots=2)
    cas = SuperSubCascade(eng, sup, specs, gen, task.sub_of_super)
    accs = []
    for b in range(12):
        x, sub, _ = task.sample(128, seed=b)
        pick = np.asarray(sub == sub[0])
        accs.append(cas.evaluate(np.asarray(x)[pick],
                                 np.asarray(sub)[pick],
                                 batch=int(pick.sum())))
    dyn = float(np.mean([a["dynamic_acc"] for a in accs]))
    sta = float(np.mean([a["static_acc"] for a in accs]))
    eng.shutdown()
    return [
        ("fig6b_static_acc", round(sta, 4), ""),
        ("fig6b_dynamic_acc", round(dyn, 4), ""),
        ("fig6b_improvement", round(dyn - sta, 4),
         "paper: up to +3.0% (dynamic >= static required)"),
    ]
