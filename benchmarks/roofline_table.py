"""Roofline table (ours): reads the dry-run JSON records and emits the
per-(arch x shape) three-term roofline, dominant bottleneck, and
useful-compute fraction.  See EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os

RESULTS_DIR = os.environ.get("REPRO_DRYRUN_DIR", "results/dryrun")


def load_records(mesh: str = "single", tag: str = "") -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(RESULTS_DIR, f"*_{mesh}*.json"))):
        stem = os.path.basename(f)[: -len(".json")]
        suffix = stem.split(f"_{mesh}")[-1]
        if suffix != (f"_{tag}" if tag else ""):
            continue
        out.append(json.load(open(f)))
    return out


def run() -> list[tuple]:
    rows = []
    recs = load_records()
    if not recs:
        return [("roofline_table", 0, "no dry-run records; run "
                 "python -m repro.launch.dryrun --all first")]
    for r in recs:
        rl = r.get("roofline")
        if not rl:
            continue
        cell = f"{r['arch']}x{r['shape']}"
        rows.append((f"roofline_{cell}_dominant", rl["dominant"],
                     f"frac={rl['roofline_fraction']:.4f}"))
        rows.append((f"roofline_{cell}_terms_s",
                     round(rl["bound_s"], 4),
                     f"c={rl['compute_s']:.4f} m={rl['memory_s']:.4f} "
                     f"x={rl['collective_s']:.4f} "
                     f"useful={rl['useful_fraction']:.2f}"))
    ok = sum(1 for r in recs if "memory_analysis" in r)
    rows.append(("dryrun_cells_compiled_single_pod", ok, ""))
    multi = load_records("multi")
    rows.append(("dryrun_cells_compiled_multi_pod", len(multi),
                 "2x16x16 = 512 chips"))
    return rows
