"""Chunked vs one-shot prefill admission under mixed prompt traffic.

One-shot admission prefills a whole prompt in one program: a long prompt
stalls every live decode row for the full prefill, and every distinct
prompt length compiles a fresh ``_admit_<S>`` program.  Chunked admission
(``StepEngine(prefill_chunk=C)``) streams the prompt into its slot in
fixed (b, C) chunks, at most one chunk per engine tick — the paper's
hide-the-load principle applied to the prompt itself: configuration
(here: prompt state) loads in bounded pieces behind active execution.

Workload: a slot pool with short requests decoding (live rows) while a
mix of LONG and short prompts arrives.  Per mode we report:

  * ``decode_stall_p99_s`` — p99 wall time of one engine tick while at
    least one live row was decoding (the latency a live token stream
    sees); one-shot admission spikes this by the whole long prefill.
  * ``ttft_p99_s`` — p99 submit-to-first-token time.
  * ``prefill_compiles`` — compiled admission programs (one-shot: one
    per distinct prompt length; chunked: ≤2 total, streaming + final).

Gates: chunked p99 decode-stall strictly below one-shot, and ≤2 chunk
programs across all prompt lengths.  CI's bench-smoke job asserts both.
"""
from __future__ import annotations

import time
from collections import deque

import numpy as np

POOL = 4
MAX_LEN = 512
CHUNK = 32
SHORT_SEQ, LONG_SEQ = 8, 448
DECODE_STEPS = 24


def _build():
    import jax
    from repro.configs import get_arch, reduced
    from repro.models.model import build_model
    cfg = reduced(get_arch("tinyllama-1.1b"))
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.key(0))


def _traffic(cfg, seed=0):
    """(tokens, steps) stream: two long-decode shorts first (they stay
    live), then alternating long/short prompts — every long admission
    lands while rows are decoding."""
    rng = np.random.default_rng(seed)

    def toks(s):
        return rng.integers(0, cfg.vocab_size, (1, s))

    reqs = [(toks(SHORT_SEQ), DECODE_STEPS), (toks(SHORT_SEQ), DECODE_STEPS)]
    # distinct long lengths: each is a fresh compile for one-shot admission
    for i, seq in enumerate((LONG_SEQ, SHORT_SEQ, LONG_SEQ - 64,
                             SHORT_SEQ + 4, LONG_SEQ - 128, SHORT_SEQ)):
        reqs.append((toks(seq), 8))
    return reqs


def _drive(eng, p, reqs):
    """Admit-when-possible + step loop; returns (stalls, ttfts)."""
    queue = deque(reqs)
    submit_at, first_at = {}, {}
    gens = []
    stalls = []
    while queue or eng.live_slots():
        t0 = time.perf_counter()
        had_live = bool(eng._live.any())
        if queue and queue[0][0].shape[0] <= eng.free_slots():
            toks, steps = queue.popleft()
            for g in eng.admit(p, toks, max_new=steps):
                submit_at[g.rid] = t0
                gens.append(g)
        eng.step(p)
        now = time.perf_counter()
        if had_live:
            stalls.append(now - t0)
        for g in gens:
            if g.tokens and g.rid not in first_at:
                first_at[g.rid] = now
    ttfts = [first_at[r] - submit_at[r] for r in submit_at]
    return stalls, ttfts


def _run_mode(chunk, m, p, cfg, passes=3):
    from repro.serve.engine import StepEngine
    eng = StepEngine(m, batch_size=POOL, max_len=MAX_LEN,
                     prefill_chunk=chunk)
    _drive(eng, p, _traffic(cfg))          # warm pass: all compiles
    # p99 over one pass's ~100 ticks is nearly a max — one OS scheduling
    # hiccup can own it.  Time several passes and keep each metric's best
    # pass: the admission-stall structure repeats every pass, the noise
    # doesn't.
    p99s, p50s, tt99s = [], [], []
    for _ in range(passes):
        eng.reset()
        stalls, ttfts = _drive(eng, p, _traffic(cfg))
        p99s.append(float(np.percentile(stalls, 99)))
        p50s.append(float(np.percentile(stalls, 50)))
        tt99s.append(float(np.percentile(ttfts, 99)))
    if chunk is None:
        compiles = eng._admit_fn._cache_size()
    else:
        compiles = (eng._chunk_fn._cache_size()
                    + eng._chunk_final_fn._cache_size())
    return {
        "decode_stall_p99_s": round(min(p99s), 5),
        "decode_stall_p50_s": round(min(p50s), 5),
        "ttft_p99_s": round(min(tt99s), 5),
        "prefill_compiles": compiles,
    }


def run() -> list[tuple]:
    cfg, m, p = _build()
    rows = []
    results = {}
    for mode, chunk in (("oneshot", None), ("chunked", CHUNK)):
        results[mode] = _run_mode(chunk, m, p, cfg)
        for k, v in results[mode].items():
            note = (f"pool {POOL}, long={LONG_SEQ} short={SHORT_SEQ} "
                    f"prompts, chunk={chunk}" if k == "decode_stall_p99_s"
                    else "")
            rows.append((f"prefill_{mode}_{k}", v, note))

    c, o = results["chunked"], results["oneshot"]
    rows.append(("chunked_stall_p99_beats_oneshot",
                 int(c["decode_stall_p99_s"] < o["decode_stall_p99_s"]),
                 f"{c['decode_stall_p99_s']} vs {o['decode_stall_p99_s']} s"))
    rows.append(("chunked_compiles_bounded",
                 int(c["prefill_compiles"] <= 2),
                 f"{c['prefill_compiles']} chunk programs vs "
                 f"{o['prefill_compiles']} one-shot (one per length)"))
    return rows


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    for row in run():
        print(*row, sep=",")
