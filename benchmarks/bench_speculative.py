"""Speculative cascade decode vs plain continuous batching.

The paper's Super-Sub cascade hides the big network's context load behind
the small network's execution.  ``SpecEngine`` is the serving analogue: a
draft context proposes K tokens per round, the target verifies all K in
ONE multi-token pass (``LM.verify_step`` / the ``verify_attention``
kernel), and draft/target hand-offs are O(1) select flips with the other
side streaming into the shadow slot.

Draft choice: the draft serves the SAME weights as the target under its
own context name.  A perfectly-aligned draft accepts every proposal, so
this measures the engine's ceiling — accepted-tokens/step = K+1 and pure
subsystem overhead (per-round host work, verify-pass cost, switch churn)
— the way a distilled production draft would approach it.  The acceptance
MECHANISM under a disagreeing draft is covered by tests
(tests/test_speculative.py): greedy output is token-identical to plain
decode for ANY draft, so the benchmark's alignment choice affects speed
only, never correctness.

Reported per mode: throughput, accepted-tokens/step, verify passes,
hidden-load fraction.  Gates: speculative must report accepted-tokens/
step > 1 and a positive hidden-load fraction (the draft/target loads
overlap execution); the paged engine must accept at least what the
retired dense-row engine did on this same harness; an equal-memory page
bank must serve at least 2x the dense-row concurrency; and adaptive K
must rise under an aligned draft and collapse under a mismatched one.
"""
from __future__ import annotations

import time

import numpy as np

TARGET = "supersub-super"
DRAFT = "supersub-super:draft"
LOAD_EMU_S = 0.03     # emulated weight-streaming time per context load
POOL = 4
MAX_LEN = 64
SPEC_K = 4
# accepted-tokens/verify-step the DENSE-ROW engine reported on this exact
# harness before its deletion (BENCH_bench_speculative.json @ PR 8): the
# paged engine must not accept less — same key schedule, same accepts
DENSE_ACCEPTED_BASELINE = 4.111


def _build(slots: int = 2, aligned_draft: bool = True):
    import jax
    from repro.configs import get_arch, reduced
    from repro.models.model import build_model
    from repro.serve.switching import ServedModel, SwitchableServer

    server = SwitchableServer(num_slots=slots)
    cfg = reduced(get_arch(TARGET))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    # a mismatched draft (fresh init) proposes near-random tokens: the
    # acceptance floor the adaptive-K controller must react to
    d_params = params if aligned_draft else model.init(jax.random.key(7))

    def weights_fn():
        time.sleep(LOAD_EMU_S)
        return params

    def draft_weights_fn():
        time.sleep(LOAD_EMU_S)
        return d_params

    server.register(ServedModel(name=TARGET, model=model,
                                weights_fn=weights_fn, max_len=MAX_LEN))
    server.register(ServedModel(name=DRAFT, model=model,
                                weights_fn=draft_weights_fn,
                                max_len=MAX_LEN))
    return server, cfg


def _stream(cfg, n_requests, seq, seed):
    rng = np.random.default_rng(seed)
    for r in range(n_requests):
        steps = [8, 20, 12][r % 3]
        yield rng.integers(0, cfg.vocab_size, (1, seq)), steps


def _drive(sched, reqs):
    t0 = time.perf_counter()
    futs = [sched.submit(TARGET, t, steps=s) for t, s in reqs]
    for f in futs:
        f.result()
    return time.perf_counter() - t0


def _run_mode(mode, n_requests, seq, seed):
    from repro.serve.scheduler import ContinuousScheduler
    server, cfg = _build()
    reqs = list(_stream(cfg, n_requests, seq, seed))

    def make():
        draft = {TARGET: DRAFT} if mode != "continuous" else None
        return ContinuousScheduler(server, batch_size=POOL, draft=draft,
                                   spec_k=SPEC_K,
                                   spec_tree=2 if mode == "tree" else 1)

    with make() as sched:                    # warm pass: jit + first loads
        _drive(sched, reqs)
    # evict everything so the measured pass pays — and hides — the context
    # loads (the warm pass left both sides resident)
    server.engine.deactivate()
    for name in list(server.engine.resident()):
        server.engine.evict(name)
    for k, v in server.engine.stats.items():
        server.engine.stats[k] = 0 if isinstance(v, int) else 0.0
    for eng in server._spec_engines.values():
        # in place: eng.stats is a registry-backed view, not a plain dict
        for k in list(eng.stats):
            eng.stats[k] = 0
    with make() as sched:
        wall = _drive(sched, reqs)
        snap = sched.snapshot()
    server.shutdown()
    return wall, snap


def _run_concurrency():
    """Equal-memory concurrency: a page bank whose two columns hold the
    bytes of 4 dense max_len rows each (16 usable pages x 16 tokens =
    256 = 4 x 64) serving short requests (2 pages/row incl. speculative
    slack) — peak concurrent rows vs the 4 the dense-row engine could
    ever hold in that memory."""
    import jax
    from repro.configs import get_arch, reduced
    from repro.models.model import build_model
    from repro.serve.speculative import SpecEngine

    page_size, num_pages = 16, 17
    cfg = reduced(get_arch(TARGET))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = SpecEngine(model, model, batch_size=8, max_len=MAX_LEN,
                     k=SPEC_K, page_size=page_size, num_pages=num_pages)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (1, 6)) for _ in range(8)]
    peak = 0
    for p in prompts:
        assert eng.can_admit(p, 8)
        eng.admit((params, params), p, max_new=8)
        peak = max(peak, eng.live_slots())
    while eng.live_slots() or eng.pending_slots():
        eng.step((params, params))
        peak = max(peak, eng.live_slots())
    equiv_rows = (num_pages - 1) * page_size // MAX_LEN
    return peak, equiv_rows


def _run_adaptive(aligned: bool, n_requests: int, seq: int, seed: int):
    """Drive the adaptive-K scheduler and report the K trajectory: an
    aligned draft (start K=1) must climb, a mismatched draft (start
    K=K_MAX) must collapse toward flat decode."""
    from repro.serve.scheduler import ContinuousScheduler
    server, cfg = _build(aligned_draft=aligned)
    reqs = list(_stream(cfg, n_requests, seq, seed))
    sched = ContinuousScheduler(server, batch_size=POOL,
                                draft={TARGET: DRAFT}, spec_k=SPEC_K,
                                spec_adaptive=True)
    eng = sched._spec_engine(TARGET)
    if aligned:
        eng.set_k(1)
    k_start = eng.k
    with sched:
        _drive(sched, reqs)
    k_end = eng.k
    server.shutdown()
    return k_start, k_end


def run(n_requests: int = 12, seq: int = 16, seed: int = 0) -> list[tuple]:
    rows = []
    n_tokens = sum([8, 20, 12][r % 3] for r in range(n_requests))
    results = {}
    for mode in ("continuous", "speculative", "tree"):
        wall, snap = _run_mode(mode, n_requests, seq, seed)
        results[mode] = {
            "wall_s": round(wall, 3),
            "tok_per_s": round(n_tokens / wall, 1),
            "hidden_load_fraction": round(snap["hidden_load_fraction"], 3),
            "loads": snap["loads"],
            "context_changes": snap["context_changes"],
        }
        if mode != "continuous":
            results[mode]["accepted_tokens_per_step"] = snap[
                "accepted_tokens_per_round"]
            results[mode]["verify_passes"] = snap["spec_rounds"]
            # fraction of drafted tokens the target accepted (1.0 for the
            # same-weights draft used here — the mechanism's ceiling)
            results[mode]["acceptance_rate"] = snap["spec_acceptance_rate"]
        for k, v in results[mode].items():
            note = (f"{n_requests} mixed-length greedy reqs, pool {POOL}, "
                    f"K={SPEC_K}" if k == "wall_s" else "")
            if k == "wall_s" and mode == "tree":
                note += ", tree W=2"
            rows.append((f"spec_{mode}_{k}", v, note))

    s = results["speculative"]
    rows.append(("spec_accepted_per_step_gt_1",
                 int(s["accepted_tokens_per_step"] > 1.0),
                 f"{s['accepted_tokens_per_step']} tokens/verify-step "
                 f"(ceiling {SPEC_K + 1})"))
    rows.append(("spec_hidden_load_fraction_positive",
                 int(s["hidden_load_fraction"] > 0),
                 "draft/target loads hidden behind execution"))
    rows.append(("spec_vs_continuous_tok_per_s",
                 round(s["tok_per_s"]
                       / max(results["continuous"]["tok_per_s"], 1e-9), 2),
                 "speculative speedup over plain continuous (same-size "
                 "draft: measures engine overhead ceiling)"))
    rows.append(("spec_paged_accepted_ge_dense",
                 int(s["accepted_tokens_per_step"]
                     >= DENSE_ACCEPTED_BASELINE),
                 f"paged {s['accepted_tokens_per_step']} vs dense-row "
                 f"baseline {DENSE_ACCEPTED_BASELINE} tokens/verify-step"))
    peak, equiv = _run_concurrency()
    rows.append(("spec_equal_mem_concurrency", round(peak / equiv, 2),
                 f"{peak} concurrent rows on a bank sized for {equiv} "
                 "dense max_len rows"))
    rows.append(("spec_equal_mem_concurrency_2x", int(peak >= 2 * equiv),
                 "paged columns serve >= 2x dense-row concurrency at "
                 "equal memory"))
    ks, ke = _run_adaptive(True, n_requests, seq, seed)
    rows.append(("spec_adaptive_k_rises", int(ke > ks),
                 f"aligned draft: K {ks} -> {ke} (ceiling {SPEC_K})"))
    ks2, ke2 = _run_adaptive(False, n_requests, seq, seed)
    rows.append(("spec_adaptive_k_falls", int(ke2 <= 2),
                 f"mismatched draft: K {ks2} -> {ke2}"))
    return rows


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    for row in run():
        print(*row, sep=",")
