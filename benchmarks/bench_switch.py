"""Live ContextSwitchEngine micro-benchmarks on this host's real JAX device:
switch latency (the paper's < 1 ns select flip -> our O(1) pointer swap),
load bandwidth, and overlap efficiency (hidden-load fraction)."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.context import ContextDescriptor, ContextSwitchEngine


def run(mb: float = 64.0) -> list[tuple]:
    n = int(mb * 1e6 / 4 / 1024)
    rng = np.random.default_rng(0)
    hosts = {name: {"w": rng.standard_normal((n, 1024)).astype(np.float32)}
             for name in ("a", "b")}
    eng = ContextSwitchEngine(num_slots=2)
    for name, host in hosts.items():
        eng.register(ContextDescriptor(
            name=name, apply_fn=lambda p, x: jnp.tanh(x @ p["w"][:256].T),
            weights_fn=lambda host=host: host))
    eng.preload("a", block=True)
    eng.preload("b", block=True)
    eng.switch("a")

    # switch latency distribution (resident -> resident)
    lat = []
    for i in range(200):
        lat.append(eng.switch("b" if i % 2 == 0 else "a"))
    lat_us = np.array(lat) * 1e6

    # load bandwidth
    eng.evict("b" if eng.active.name == "a" else "a")
    other = "b" if eng.active.name == "a" else "a"
    t0 = time.perf_counter()
    eng.preload(other, block=True)
    load_s = time.perf_counter() - t0
    gbps = mb / 1e3 / load_s

    # overlap efficiency: run the active net while the other loads
    eng.evict("a" if eng.active.name == "b" else "b")
    x = jnp.ones((512, 1024))
    eng.run(x)                                  # warm the executable
    other = "a" if eng.active.name == "b" else "b"
    t0 = time.perf_counter()
    fut = eng.preload(other)
    execs = 0
    while not fut.done():
        eng.run(x)
        execs += 1
    overlap_wall = time.perf_counter() - t0
    eng.switch(other)
    hidden_frac = min(1.0, execs and (overlap_wall / max(load_s, 1e-9)))

    rows = [
        ("switch_latency_us_p50", round(float(np.percentile(lat_us, 50)), 2),
         "O(1) pointer swap"),
        ("switch_latency_us_p99", round(float(np.percentile(lat_us, 99)), 2),
         ""),
        ("context_load_s_64MB", round(load_s, 4), f"{gbps:.2f} GB/s"),
        ("switch_vs_load_ratio",
         round(float(np.percentile(lat_us, 50)) / (load_s * 1e6), 8),
         "paper: <1ns switch vs ms-scale reconfig"),
        ("execs_completed_during_load", execs,
         "execution uninterrupted by shadow-slot load"),
    ]
    eng.shutdown()
    return rows
