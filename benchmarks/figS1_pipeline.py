"""Fig S1(a): pipelined cascade — while the super net classifies batch i+1,
the specialist for batch i streams into the shadow slot.  The paper's cycle
model: 8 cycles for 4 images (ours) vs 16+ (serial reload)."""
from __future__ import annotations

import numpy as np

from benchmarks._members import build_cascade_members
from repro.core.cascade import SuperSubCascade
from repro.core.context import ContextSwitchEngine
from repro.train.data import HierarchicalTask


def cycle_model(n_images: int = 4) -> tuple[int, int]:
    """The paper's abstract cycle count: each stage = 1 cycle; serial FPGA
    reloads (1 cycle each) between super and specialist per image."""
    ours = n_images + 4                      # pipelined: fill + drain
    conv = 4 * n_images                      # load+super+load+spec per image
    return ours, conv


def run() -> list[tuple]:
    ours, conv = cycle_model(4)
    rows = [("figS1a_cycles_ours_4img", ours, "paper: 8"),
            ("figS1a_cycles_conventional_4img", conv, "paper: 16+")]

    # live: pipelined dynamic inference over 6 batches
    task = HierarchicalTask(num_super=4, subs_per_super=3, vocab=64,
                            seq_len=32, seed=0)
    sup, gen, specs = build_cascade_members(task)
    eng = ContextSwitchEngine(num_slots=3)
    cas = SuperSubCascade(eng, sup, specs, gen, task.sub_of_super)
    batches = [np.asarray(task.sample(16, seed=b,
                                      subclasses=np.array([3 * (b % 4)]))[0])
               for b in range(6)]
    import time
    t0 = time.perf_counter()
    out = cas.dynamic_infer_pipelined(batches)
    wall = time.perf_counter() - t0
    rows.append(("figS1a_live_pipelined_batches", len(out),
                 f"wall={wall:.3f}s hidden_loads="
                 f"{eng.stats['loads'] - 1}"))
    eng.shutdown()
    return rows
