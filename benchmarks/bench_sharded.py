"""Sharded page bank vs single-shard paged pool at EQUAL PER-DEVICE
memory.

A single-device paged engine is capped by its one bank: concurrency
stops where the free-list empties.  Sharding the bank over N devices
multiplies the page budget by N while each device still holds one
bank-slice of the same size — the paper's context-switching argument at
rack scale: add devices, keep per-device area fixed, serve N times the
concurrent requests.  The host-side cost is only the per-shard
free-lists and the admission router.

Two measurements (CI's ``multi-device`` job runs this under
``--xla_force_host_platform_device_count=4`` and asserts both gates):

  * ``peak_concurrency`` — admit-greedy short-request burst through a
    1-shard pool with a per-device page budget vs a 4-shard pool with
    the SAME budget per shard.  Gate: sharded >= 1.8x single
    (``sharded_concurrency_1_8x``; the ideal is 4x, the gate leaves
    headroom for slot-bound tails).
  * ``sharded_stream_identical`` — the signature invariant as a gate
    row: greedy + seeded-temperature streams from the 4-shard engine,
    bitwise-equal to the single-shard engine's.  Sharding only changes
    WHICH pool pages a table points at, and the gather through the
    table is permutation-invariant in page ids.
"""
from __future__ import annotations

import numpy as np

SHARDS = 4
PAGE = 16
MAX_LEN = 64
PER_SHARD_PAGES = 9                  # 8 allocatable + reserved local 0
SEQ, STEPS = 8, 7                    # 8 + 7 < 16: one page per request
N_REQS = 40


def _build():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch, reduced
    from repro.models.model import build_model
    cfg = reduced(get_arch("tinyllama-1.1b"), dtype="float32",
                  param_dtype="float32")
    m = build_model(cfg, cache_dtype=jnp.float32)
    return cfg, m, m.init(jax.random.key(0))


def _burst(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (1, SEQ))
            for _ in range(N_REQS)]


def _peak_concurrency(eng, p, reqs):
    queue = list(reqs)
    peak = 0
    while queue or eng.live_slots():
        while queue and eng.can_admit(queue[0], STEPS):
            eng.admit(p, queue.pop(0), max_new=STEPS)
        peak = max(peak, eng.live_slots())
        if eng.live_slots():
            eng.step(p)
    return peak


def _stream(eng, p, cfg, temperature):
    """Staggered two-request stream; returns the emitted token lists."""
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, (1, 8)),
               rng.integers(0, cfg.vocab_size, (1, 24))]
    seeds = [7, 9] if temperature else [None, None]
    gens = [eng.admit(p, prompts[0], max_new=5, seeds=[seeds[0]])[0]]
    for _ in range(2):
        eng.step(p)
    gens.append(eng.admit(p, prompts[1], max_new=5, seeds=[seeds[1]])[0])
    while eng.live_slots():
        eng.step(p)
    return [g.tokens for g in gens]


def run() -> list[tuple]:
    import jax
    from repro.distributed.mesh import make_mesh
    from repro.serve.engine import StepEngine
    cfg, m, p = _build()
    devs = jax.device_count()
    mesh = (make_mesh((SHARDS,), ("model",)) if devs >= SHARDS else None)

    single = StepEngine(m, batch_size=PER_SHARD_PAGES - 1, max_len=MAX_LEN,
                        paged=True, page_size=PAGE,
                        num_pages=PER_SHARD_PAGES)
    sharded = StepEngine(m, batch_size=SHARDS * (PER_SHARD_PAGES - 1),
                         max_len=MAX_LEN, paged=True, page_size=PAGE,
                         shards=SHARDS, mesh=mesh,
                         num_pages=SHARDS * PER_SHARD_PAGES)
    peak_one = _peak_concurrency(single, p, _burst(cfg))
    peak_sharded = _peak_concurrency(sharded, p, _burst(cfg))
    ratio = peak_sharded / peak_one if peak_one else 0.0

    # bitwise gate: sharded streams == single-shard streams, greedy and
    # seeded temperature (fresh engines: clean pools, same jit keys)
    identical = 1
    for temp in (0.0, 0.8):
        one = StepEngine(m, batch_size=2, max_len=MAX_LEN, paged=True,
                         page_size=PAGE, temperature=temp,
                         num_pages=PER_SHARD_PAGES)
        sh = StepEngine(m, batch_size=2, max_len=MAX_LEN, paged=True,
                        page_size=PAGE, temperature=temp, shards=SHARDS,
                        mesh=mesh, num_pages=SHARDS * PER_SHARD_PAGES)
        if _stream(sh, p, cfg, temp) != _stream(one, p, cfg, temp):
            identical = 0

    budget = f"{PER_SHARD_PAGES} pages of {PAGE} per device"
    return [
        ("single_peak_concurrency", peak_one,
         f"1 shard, {budget}"),
        ("sharded_peak_concurrency", peak_sharded,
         f"{SHARDS} shards x {budget}"
         + (f", mesh over {devs} devices" if mesh is not None
            else f", host-only ({devs} device(s))")),
        ("sharded_concurrency_1_8x", int(ratio >= 1.8),
         f"{peak_sharded} vs {peak_one} concurrent "
         f"({ratio:.2f}x at equal per-device memory)"),
        ("sharded_stream_identical", identical,
         "greedy + seeded temperature streams bitwise-equal to the "
         "single-shard paged engine"),
        ("shard_pages_admitted",
         int(sum(v for k, v in
                 sharded.telemetry.registry.snapshot().items()
                 if "shard." in k and k.endswith("admitted_pages"))),
         "pages routed through the per-shard free-lists"),
    ]


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    for row in run():
        print(*row, sep=",")
