"""End-to-end training driver: a ~100M-parameter xLSTM-125m (the one
assigned arch that IS ~100M at full config) for a few hundred steps with
checkpoint/restart, on whatever devices exist.

On CPU this uses a width-reduced variant by default so a few hundred steps
finish in minutes; pass --full on real hardware for the true 125M run.

    PYTHONPATH=src python examples/train_100m.py --steps 300
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import get_arch, override, reduced
from repro.configs.base import OptimizerConfig, ParallelConfig, RunConfig
from repro.distributed.mesh import make_mesh
from repro.models.model import build_model
from repro.train.data import PrefetchLoader, SyntheticTokens
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true",
                    help="true 125M config (use on TPU)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m")
    args = ap.parse_args()

    cfg = get_arch("xlstm-125m")
    if not args.full:
        cfg = override(reduced(cfg), d_model=256, num_heads=4, head_dim=64,
                       num_layers=4, vocab_size=8192,
                       name="xlstm-30m-dev")
    ndev = len(jax.devices())
    mesh = make_mesh((ndev, 1), ("data", "model")) if ndev > 1 else None
    model = build_model(cfg, mesh=mesh)
    print(f"{cfg.name}: {model.n_params() / 1e6:.1f}M params, "
          f"{ndev} device(s)")

    run_cfg = RunConfig(
        arch=cfg.name,
        optimizer=OptimizerConfig(lr=6e-4, total_steps=args.steps,
                                  warmup_steps=args.steps // 20 + 1),
        parallel=ParallelConfig(remat="full", microbatches=1),
        checkpoint_dir=args.ckpt, checkpoint_every=100, log_every=20)

    src = SyntheticTokens(cfg.vocab_size, args.seq, args.batch, seed=0)
    data = PrefetchLoader(src, depth=2, deadline_s=10.0)  # straggler-safe
    trainer = Trainer(model, run_cfg, data, mesh=mesh)
    state = trainer.init_or_restore(jax.random.key(0))
    if trainer.start_step:
        print(f"resumed from step {trainer.start_step}")
    state = trainer.train(
        state, args.steps,
        log_cb=lambda m: print(f"step {m['step']:4d}  loss {m['loss']:.4f}"
                               f"  {m['sec_per_step']:.2f}s/step"))
    print(f"stragglers served from backup: {data.stats['stragglers']}")
    data.close()


if __name__ == "__main__":
    main()
