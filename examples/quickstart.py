"""Quickstart: build any assigned architecture, train a few steps, then
serve it — the whole public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py [--arch mixtral-8x7b]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_arch, list_archs, reduced
from repro.configs.base import OptimizerConfig, RunConfig
from repro.models.model import build_model
from repro.serve.engine import ServingEngine
from repro.train.data import SyntheticTokens
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=list_archs())
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    # 1. config: the exact assigned architecture, smoke-scaled for CPU
    cfg = reduced(get_arch(args.arch))
    print(f"arch={cfg.name} family={cfg.family} "
          f"layers={cfg.num_layers} d_model={cfg.d_model}")

    # 2. model: one composable LM covers dense/MoE/SSM/xLSTM/hybrid/VLM
    model = build_model(cfg)
    print(f"params: {model.n_params() / 1e6:.2f}M")

    # 3. train on the synthetic pipeline (checkpointing on by default)
    run_cfg = RunConfig(arch=cfg.name, checkpoint_dir="/tmp/quickstart_ckpt",
                        optimizer=OptimizerConfig(lr=1e-3,
                                                  total_steps=args.steps))
    data = SyntheticTokens(cfg.vocab_size, seq_len=64, batch=8)
    trainer = Trainer(model, run_cfg, data)
    state = trainer.init_or_restore(jax.random.key(0))
    state = trainer.train(state, args.steps,
                          log_cb=lambda m: print(f"  step {m['step']}: "
                                                 f"loss {m['loss']:.4f}"))

    # 4. serve: prefill + decode with the trained weights
    engine = ServingEngine(model, state["params"], max_len=96)
    prompt = np.asarray(data.batch_at(0)["tokens"][:2, :16])
    out = engine.generate(prompt, steps=12)
    print(f"generated token ids:\n{out}")
    print(f"decode throughput: {engine.stats.tok_per_s:.1f} tok/s")


if __name__ == "__main__":
    main()
