"""End-to-end driver (paper Fig 6a/b): TRAIN the Super-Sub cascade members
— a generalist, a superclass router, and per-superclass specialists — then
run dynamic inference through the context-switching engine and compare
against static inference.

    PYTHONPATH=src python examples/train_cascade.py [--steps 300]

This is the paper's flagship workload built end-to-end in the framework:
real (small) transformer classifiers, real training loop, real engine.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.configs.base import OptimizerConfig
from repro.core.cascade import CascadeMember, SuperSubCascade
from repro.core.context import ContextSwitchEngine
from repro.models.model import build_model
from repro.train.data import HierarchicalTask
from repro.train.optimizer import adamw_init, adamw_update, make_schedule


def make_classifier(cfg, num_classes: int, key):
    """Mean-pooled transformer encoder head over the LM backbone."""
    model = build_model(cfg)
    params = model.init(key)
    head = jax.random.normal(key, (cfg.d_model, num_classes)) * 0.02
    return model, {"backbone": params, "head": head}


def apply_classifier(model, params, tokens):
    h, _ = model.hidden(params["backbone"], tokens)
    return h.mean(axis=1) @ params["head"]


def train_classifier(model, params, batches, steps, num_classes, lr=2e-3):
    ocfg = OptimizerConfig(lr=lr, total_steps=steps,
                           warmup_steps=max(steps // 10, 1))
    sched = make_schedule(ocfg)
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, x, y):
        def loss_fn(p):
            logits = apply_classifier(model, p, x)
            onehot = jax.nn.one_hot(y, num_classes)
            return -jnp.mean(jnp.sum(
                jax.nn.log_softmax(logits) * onehot, -1))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = adamw_update(grads, opt, params, ocfg, sched)
        return params, opt, loss

    loss = None
    for i in range(steps):
        b = next(batches)
        params, opt, loss = step(params, opt, b["x"], b["label"])
    return params, float(loss)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--num-super", type=int, default=3)
    ap.add_argument("--subs-per-super", type=int, default=3)
    args = ap.parse_args()

    task = HierarchicalTask(num_super=args.num_super,
                            subs_per_super=args.subs_per_super,
                            vocab=256, seq_len=24, seed=0,
                            super_strength=3.0, sub_strength=1.5)
    num_sub = task.num_sub
    cfg = reduced(get_arch("supersub-super"),
                  vocab_size=task.vocab, num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128)

    def batches(label_key, subclasses=None, seed=0):
        it = task.batch_iter(32, seed=seed, subclasses=subclasses)
        while True:
            b = next(it)
            yield {"x": b["x"], "label": b[label_key]}

    t0 = time.time()
    # --- train the three kinds of members --------------------------------
    print("training superclass router ...")
    sup_model, sup_p = make_classifier(cfg, task.num_super, jax.random.key(1))
    sup_p, l = train_classifier(sup_model, sup_p, batches("sup", seed=1),
                                args.steps, task.num_super)
    print(f"  router loss {l:.3f}")

    print("training generalist (all subclasses, same budget) ...")
    gen_model, gen_p = make_classifier(cfg, num_sub, jax.random.key(2))
    gen_p, l = train_classifier(gen_model, gen_p, batches("sub", seed=2),
                                args.steps, num_sub)
    print(f"  generalist loss {l:.3f}")

    specialists = []
    for g in range(task.num_super):
        subs = np.where(task.sub_of_super == g)[0]
        k = len(subs)
        model_s, p_s = make_classifier(cfg, k, jax.random.key(10 + g))

        def local_batches(subs=subs, g=g):
            it = task.batch_iter(32, seed=50 + g, subclasses=subs)
            while True:
                b = next(it)
                local = jnp.searchsorted(jnp.asarray(subs), b["sub"])
                yield {"x": b["x"], "label": local}

        p_s, l = train_classifier(model_s, p_s, local_batches(),
                                  args.steps, k)
        print(f"  specialist {g} loss {l:.3f}")
        specialists.append((model_s, p_s, g))

    # --- wire everything into the context-switching engine ----------------
    eng = ContextSwitchEngine(num_slots=2)
    sup_m = CascadeMember(
        "super", lambda p, x: apply_classifier(sup_model, p, x),
        lambda: sup_p)
    gen_m = CascadeMember(
        "generalist", lambda p, x: apply_classifier(gen_model, p, x),
        lambda: gen_p)
    spec_ms = [CascadeMember(
        f"spec{g}", lambda p, x, m=m: apply_classifier(m, p, x),
        lambda p=p: p, covers=g) for m, p, g in specialists]
    cascade = SuperSubCascade(eng, sup_m, spec_ms, gen_m, task.sub_of_super)

    # --- evaluate: dynamic (paper Fig 6a) vs static ------------------------
    res = []
    for b in range(8):
        x, sub, sup = task.sample(64, seed=500 + b,
                                  subclasses=np.array(
                                      [task.subs_per_super * (b % args.num_super)]))
        res.append(cascade.evaluate(np.asarray(x), np.asarray(sub),
                                    batch=64))
    dyn = np.mean([r["dynamic_acc"] for r in res])
    sta = np.mean([r["static_acc"] for r in res])
    print(f"\nstatic accuracy  : {sta:.3f}")
    print(f"dynamic accuracy : {dyn:.3f}  (improvement {dyn - sta:+.3f})")
    print(f"engine: {eng.stats['switches']} switches "
          f"({1e6 * eng.stats['switch_seconds'] / max(eng.stats['switches'], 1):.1f} us avg), "
          f"{eng.stats['loads']} loads")
    print(f"total wall: {time.time() - t0:.1f}s")
    eng.shutdown()


if __name__ == "__main__":
    main()
