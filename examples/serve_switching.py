"""Context-switching serving across heterogeneous architectures (the
paper's case studies 2 & 3, live): a dense llama, an MoE, and an xLSTM take
turns serving request batches.

  * preloaded pair  -> switch cost is an O(1) activation flip (case 2)
  * third model     -> streams into the shadow slot while another serves,
                       so its reconfiguration is (partially) hidden (case 3)
  * finally the same traffic goes through the async ``SwitchScheduler``,
    which coalesces same-model requests and prefetches the next model by
    queue pressure — far fewer switches for the same answers.

    PYTHONPATH=src python examples/serve_switching.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.models.model import build_model
from repro.serve.scheduler import SwitchScheduler
from repro.serve.switching import ServedModel, SwitchableServer

ARCHS = ["tinyllama-1.1b", "mixtral-8x7b", "xlstm-125m"]


def main():
    server = SwitchableServer(num_slots=2)
    cfgs = {}
    for i, name in enumerate(ARCHS):
        cfg = reduced(get_arch(name))
        cfgs[name] = cfg
        model = build_model(cfg)
        params = model.init(jax.random.key(i))
        server.register(ServedModel(name=name, model=model,
                                    weights_fn=lambda p=params: p,
                                    max_len=48))
        print(f"registered {name:16s} "
              f"({model.n_params() / 1e6:.2f}M params)")

    rng = np.random.default_rng(0)
    # request stream: llama<->mixtral ping-pong (case 2), xlstm arrives
    # mid-stream (case 3: load hidden behind the active model's batches)
    stream = (["tinyllama-1.1b", "mixtral-8x7b"] * 3 +
              ["xlstm-125m", "tinyllama-1.1b", "xlstm-125m"])
    batches = [rng.integers(0, cfgs[n].vocab_size, (4, 24)) for n in stream]
    t0 = time.perf_counter()
    for i, (name, toks) in enumerate(zip(stream, batches)):
        server.engine.prefetch(stream[i + 1:], limit=1)  # dynamic reconfig
        out = server.serve_batch(name, toks)
        rec = server.log[-1]
        print(f"req {i:2d} -> {name:16s} switch={rec['switch_s'] * 1e6:7.1f}us "
              f"total={rec['total_s'] * 1e3:7.1f}ms")
    wall = time.perf_counter() - t0

    s = server.engine.stats
    print(f"\n{len(stream)} requests over {len(ARCHS)} models in {wall:.2f}s")
    print(f"switches: {s['switches']}  ({s['context_changes']} context "
          f"changes, avg "
          f"{1e6 * s['switch_seconds'] / max(s['switches'], 1):.1f} us — "
          f"the paper's <1ns select-flip analogue)")
    print(f"loads: {s['loads']}  (avg "
          f"{1e3 * s['load_seconds'] / max(s['loads'], 1):.1f} ms, "
          f"{s['bytes_loaded'] / 1e6:.1f} MB total — "
          f"hidden behind execution where the stream allowed)")

    # same traffic, request-level scheduling: the SwitchScheduler coalesces
    # per-model backlogs into streaks and prefetches by queue pressure
    changes_before = s["context_changes"]
    t0 = time.perf_counter()
    with SwitchScheduler(server) as sched:
        futs = [sched.submit(n, t) for n, t in zip(stream, batches)]
        for f in futs:
            f.result()
    q_wall = time.perf_counter() - t0
    q_changes = s["context_changes"] - changes_before
    print(f"\nqueued mode: {len(stream)} requests in {q_wall:.2f}s with "
          f"{q_changes} context changes (vs {changes_before} synchronous) — "
          f"{sched.stats['stacked_requests']} requests stacked into joint "
          f"batches")
    server.shutdown()


if __name__ == "__main__":
    main()
