"""Glossary check: every metric key the serving stack emits must be
documented in docs/observability.md.

Runs a mini traced serve exercising every emitting layer — context
switching, plain + chunked + paged/prefix step engines, a speculative
engine, both schedulers, the run-to-completion wrapper, and the
discrete-event simulator — then asserts every `registry.keys()` entry
matches a backticked name or glob pattern in the glossary.  CI runs
this so a new counter cannot ship undocumented.

Usage: PYTHONPATH=src python tools/check_metric_docs.py
"""
from __future__ import annotations

import fnmatch
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT / "tests"))

DOC = ROOT / "docs" / "observability.md"


def emitted_keys() -> list[str]:
    import jax
    import numpy as np
    from conftest import reduced_arch, tokens_for
    from repro.core.scheduler import Run, simulate_dynamic
    from repro.core.telemetry import ManualClock, Telemetry
    from repro.models.model import build_model
    from repro.serve.scheduler import ContinuousScheduler, SwitchScheduler
    from repro.serve.switching import ServedModel, SwitchableServer

    tm = Telemetry(trace=True)
    server = SwitchableServer(num_slots=2, telemetry=tm)
    cfgs = {}
    for i, name in enumerate(["supersub-super", "supersub-sub"]):
        cfg = reduced_arch(name)
        cfgs[name] = cfg
        m = build_model(cfg)
        p = m.init(jax.random.key(i))
        server.register(ServedModel(name=name, model=m,
                                    weights_fn=lambda p=p: p, max_len=64))
    names = list(cfgs)

    def toks(nm, seed, seq=8):
        return np.asarray(tokens_for(cfgs[nm], batch=1, seq=seq, seed=seed))

    # streak scheduler (sched.batches/streaks/stacked_requests)
    with SwitchScheduler(server) as sched:
        for f in [sched.submit(names[i % 2], toks(names[i % 2], i),
                               steps=2) for i in range(4)]:
            f.result(timeout=300)
    # continuous: paged + prefix-cache + chunked + multi-step covers the
    # page/prefix/chunk counters in one pass
    with ContinuousScheduler(server, batch_size=4, paged=True,
                             page_size=16, prefix_cache=True,
                             prefill_chunk=8, multi_step=2) as sched:
        shared = toks(names[0], 99, seq=32)
        futs = [sched.submit(names[0], shared, steps=3) for _ in range(3)]
        for f in futs:
            f.result(timeout=300)
    sched.snapshot()
    # speculative engine (rounds / committed_tokens / ...)
    with ContinuousScheduler(server, batch_size=4,
                             draft={names[0]: names[1]}) as sched:
        sched.submit(names[0], toks(names[0], 7), steps=3).result(
            timeout=300)
    sched.snapshot()
    # run-to-completion wrapper (prefill_s / decode_s / tokens)
    server.serve_batch(names[0], toks(names[0], 3), steps=2)
    # simulator writes the live ctx.* keys + visible_stall_seconds
    simulate_dynamic([Run("a", 1.0), Run("b", 1.0)], {"a": 0.5, "b": 0.5},
                     telemetry=Telemetry(clock=ManualClock()))
    sim_tm = Telemetry(clock=ManualClock())
    simulate_dynamic([Run("a", 1.0), Run("b", 1.0)], {"a": 0.5, "b": 0.5},
                     telemetry=sim_tm)
    server.shutdown()
    return sorted(set(tm.registry.keys()) | set(sim_tm.registry.keys()))


def glossary_patterns() -> list[str]:
    """Backticked tokens in the doc that look like metric keys/patterns."""
    text = DOC.read_text()
    out = []
    for tok in re.findall(r"`([^`\s]+)`", text):
        if re.fullmatch(r"[A-Za-z0-9_.*<>-]+", tok):
            # normalize doc placeholders like eng.<i>. to globs
            out.append(re.sub(r"<[^>]+>", "*", tok))
    return out


def main() -> int:
    pats = glossary_patterns()
    keys = emitted_keys()
    undocumented = [k for k in keys
                    if not any(fnmatch.fnmatchcase(k, p) for p in pats)]
    print(f"{len(keys)} emitted keys, {len(pats)} glossary patterns")
    if undocumented:
        print("UNDOCUMENTED metric keys (add to docs/observability.md):")
        for k in undocumented:
            print(f"  {k}")
        return 1
    print("all emitted metric keys are documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
